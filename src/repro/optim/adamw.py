"""AdamW + global-norm clipping + LR schedules — from scratch (no optax).

State layout mirrors the param tree: {"m": tree, "v": tree, "step": scalar}.
Moment dtype is configurable (fp32 default; bf16 halves optimizer HBM — a
documented memory-roofline lever for the 398B configs, see EXPERIMENTS.md).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    master_weights: bool = False      # keep an fp32 master copy in the
                                      # optimizer; lets params live in bf16
                                      # (halving FSDP gathers + grad
                                      # reductions) without update drift
    schedule: str = "cosine"          # constant|cosine|linear
    warmup_steps: int = 100
    total_steps: int = 10000


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 1.0 - frac
    else:  # cosine
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def init_opt_state(params: Any, cfg: AdamWConfig) -> Dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_weights:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw_update(params: Any, grads: Any, state: Dict, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v, master):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g32) * (1 - cfg.b2)
        mhat = m32 / b1c
        vhat = v32 / b2c
        ref = master if master is not None else p.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * ref
        new_master = ref - lr * delta
        return (new_master.astype(p.dtype), m32.astype(mdt),
                v32.astype(mdt), new_master)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_w = (jax.tree.leaves(state["master"])
              if cfg.master_weights else [None] * len(flat_p))
    new_p, new_m, new_v, new_w = [], [], [], []
    for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_w):
        np_, nm, nv, nw = upd(p, g, m, v, w)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
        new_w.append(nw)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    if cfg.master_weights:
        new_state["master"] = jax.tree.unflatten(treedef, new_w)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
