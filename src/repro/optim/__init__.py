from .adamw import (
    AdamWConfig, adamw_update, clip_by_global_norm, global_norm,
    init_opt_state, schedule_lr,
)

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "schedule_lr",
           "global_norm", "clip_by_global_norm"]
