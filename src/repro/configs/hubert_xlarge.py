"""hubert-xlarge [audio] — arXiv:2106.07447 (config unverified tier).

48L encoder-only transformer backbone, d_model 1280, 16H (kv=16), d_ff
5120, 504 output classes (masked-unit prediction).  The conv waveform
frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings [B, S, d_model].  Bidirectional attention
(causal=False) — no decode step, so decode_32k/long_500k are skipped
(DESIGN.md §5).  RoPE stands in for HuBERT's conv positional embedding
(hardware-adaptation note in DESIGN.md §8).
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    pattern=(LayerSpec("attn", "mlp"),),
    causal=False,
    input_mode="embeddings",
    tie_embeddings=False,
    act="geglu",
)
