"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B.

48L, d_model 2048, 32H (GQA kv=4, head_dim 128), vocab 151936.
MoE 128 experts top-8, expert d_ff 768, QK-RMSNorm, untied embeddings.
~30B total, ~3B active per token.
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    pattern=(LayerSpec("attn", "moe"),),
    moe_experts=128,
    moe_topk=8,
    moe_d_ff=768,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
)
