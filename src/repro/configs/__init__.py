"""Architecture registry + the assigned (arch × input-shape) matrix.

``--arch <id>`` everywhere resolves through ``get_config``.  ``CELLS``
enumerates the dry-run/roofline matrix with the skip rules of DESIGN.md §5:
  * encoder-only archs have no decode step  → skip decode_32k, long_500k
  * pure full-attention archs               → skip long_500k
  * SSM / hybrid archs                      → run long_500k
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..models import ModelConfig
from . import (
    gemma2_9b, gemma_2b, granite_8b, grok_1_314b, hubert_xlarge,
    jamba_1_5_large_398b, mamba2_780m, qwen2_vl_7b, qwen3_14b,
    qwen3_moe_30b_a3b,
)

REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        jamba_1_5_large_398b, gemma2_9b, qwen3_14b, granite_8b, gemma_2b,
        grok_1_314b, qwen3_moe_30b_a3b, hubert_xlarge, qwen2_vl_7b,
        mamba2_780m,
    )
}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.kind == "decode" and not cfg.is_decoder:
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return "pure full-attention arch; 500k decode needs sub-quadratic attention"
    return None


def cells(include_skipped: bool = False
          ) -> List[Tuple[ModelConfig, ShapeSpec, Optional[str]]]:
    out = []
    for cfg in REGISTRY.values():
        for shape in SHAPES.values():
            reason = cell_skip_reason(cfg, shape)
            if reason is None or include_skipped:
                out.append((cfg, shape, reason))
    return out


CELLS = cells()

__all__ = ["REGISTRY", "ARCH_IDS", "get_config", "ShapeSpec", "SHAPES",
           "cells", "CELLS", "cell_skip_reason"]
