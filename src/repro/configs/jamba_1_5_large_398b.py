"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887 / 2408.12570.

72L, d_model 8192, 64H (GQA kv=8), d_ff 24576, vocab 65536, MoE 16e top-2.
Mamba:attention 1:7 interleave (one attention layer per 8-layer Jamba
block, at index 4 as in the paper), MoE every other layer.
Runs long_500k: the attention minority + O(1) SSM state keep decode
sub-quadratic in context (DESIGN.md §5).
"""
from repro.models import LayerSpec, ModelConfig

_PATTERN = tuple(
    LayerSpec("attn" if i == 4 else "mamba",
              "moe" if i % 2 == 1 else "mlp")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=_PATTERN,
    moe_experts=16,
    moe_topk=2,
    moe_d_ff=24576,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    act="swiglu",
    seq_shard=False,   # SSD chunk scan must not cross sequence shards
)
