"""mamba2-780m [ssm] — arXiv:2405.21060 (SSD; config unverified tier).

48L attention-free, d_model 1536, d_inner 3072 (expand 2), 48 SSD heads of
headdim 64, d_state 128, vocab 50280.  Pure Mamba-2 blocks (norm → SSD →
residual; no separate FFN).  Decode state is O(1) per layer → runs
long_500k.  Vocab 50280 is 16-indivisible → embeddings replicate
(77M — negligible).
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerSpec("mamba", "none"),),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    seq_shard=False,   # SSD chunk scan must not cross sequence shards
)
