"""qwen2-vl-7b [vlm] — arXiv:2409.12191.

28L decoder backbone, d_model 3584, 28H (GQA kv=4, head_dim 128), d_ff
18944, vocab 152064.  M-RoPE (temporal/height/width position streams over
rotary sections 16/24/24).  The vision tower is a STUB per the assignment:
``input_specs()`` supplies precomputed patch/text embeddings [B, S, d] and
a [3, B, S] position tensor.  28 heads are 16-indivisible → TP shards
head_dim.
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    pattern=(LayerSpec("attn", "mlp"),),
    mrope=True,
    rope_theta=1000000.0,
    input_mode="embeddings",
    tie_embeddings=False,
)
