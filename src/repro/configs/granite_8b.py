"""granite-8b [dense] — arXiv:2405.04324 (Granite Code).

36L, d_model 4096, 32H (GQA kv=8), d_ff 14336, vocab 49152. Llama-style
pre-norm decoder, SwiGLU, tied embeddings.
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    pattern=(LayerSpec("attn", "mlp"),),
    rope_theta=10000000.0,
    tie_embeddings=True,
)
