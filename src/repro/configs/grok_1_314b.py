"""grok-1-314b [moe] — hf:xai-org/grok-1 (config unverified).

64L, d_model 6144, 48H (GQA kv=8, head_dim 128), d_ff 32768, vocab 131072.
MoE 8 experts top-2 on every layer.  8 experts are 16-indivisible → expert
weights replicate across the expert-parallel axis and each expert's d_ff
shards over "model" (DESIGN.md §6).
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    pattern=(LayerSpec("attn", "moe"),),
    moe_experts=8,
    moe_topk=2,
    moe_d_ff=32768,
    tie_embeddings=True,
)
