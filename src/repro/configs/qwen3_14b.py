"""qwen3-14b [dense] — hf:Qwen/Qwen3-14B.

40L, d_model 5120, 40H (GQA kv=8, head_dim 128), d_ff 17408, vocab 151936.
Per-head QK-RMSNorm, untied embeddings.  40 heads are 16-indivisible, so
tensor parallelism shards head_dim (interleaved-RoPE keeps pairs local —
DESIGN.md §6).
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    pattern=(LayerSpec("attn", "mlp"),),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
)
