"""gemma2-9b [dense] — arXiv:2408.00118.

42L, d_model 3584, 16H (GQA kv=8, head_dim 256), d_ff 14336, vocab 256000.
Local(4096-window)/global alternating attention, attention-logit softcap 50,
final-logit softcap 30, GeGLU, scaled embeddings, zero-centered RMSNorm.
Skips long_500k (global layers are full attention — DESIGN.md §5).
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    pattern=(LayerSpec("attn_local", "mlp"), LayerSpec("attn_global", "mlp")),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="geglu",
    embed_scale=True,
    zero_centered_norm=True,
    tie_embeddings=True,
)
