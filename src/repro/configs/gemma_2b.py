"""gemma-2b [dense] — arXiv:2403.08295.

18L, d_model 2048, 8H (MQA kv=1, head_dim 256), d_ff 16384, vocab 256000.
GeGLU, scaled embeddings, zero-centered RMSNorm, tied embeddings.
8 heads are 16-indivisible → TP shards head_dim (256/16 = 16).
"""
from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    pattern=(LayerSpec("attn", "mlp"),),
    act="geglu",
    embed_scale=True,
    zero_centered_norm=True,
    tie_embeddings=True,
)
