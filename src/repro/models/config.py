"""Model configuration covering all assigned architecture families.

A model is a stack of ``n_layers`` transformer-ish blocks described by a
repeating ``pattern`` of ``LayerSpec``s (mixer + ffn).  The stack is
executed as ``lax.scan`` over ``n_layers // len(pattern)`` *groups* with the
pattern unrolled inside the body — HLO size is O(pattern), not O(depth),
which is what lets 72-layer/398B graphs compile in seconds (MaxText does
the same).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class LayerSpec:
    mixer: str            # "attn" | "attn_local" | "attn_global" | "mamba"
    ffn: str              # "mlp" | "moe" | "none"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn", "mlp"),)

    # attention details
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    sliding_window: int = 0
    causal: bool = True
    mrope: bool = False
    embed_scale: bool = False        # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True
    zero_centered_norm: bool = False # gemma (1+scale) RMSNorm
    act: str = "swiglu"

    # input modality: "tokens" (LM) or "embeddings" (stubbed frontend)
    input_mode: str = "tokens"

    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba / hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # execution
    remat: bool = True               # checkpoint each scan group in training
    remat_policy: str = "nothing"    # "nothing": recompute all (min memory)
                                     # "dots": save matmul outputs, skip
                                     # their recompute (+weight re-gathers)
    attn_chunk: int = 1024           # KV-chunked online-softmax attention;
                                     # 0 = naive S² materialization
    scan_unroll: bool = False        # unroll the group scan (cost analysis)
    use_pallas: bool = False         # route attention through the Pallas
                                     # flash kernel (compiled on TPU;
                                     # interpret-mode elsewhere — slow on
                                     # CPU, for validation only)
    seq_shard: bool = True           # Megatron-style sequence parallelism:
                                     # activations (and the remat stash)
                                     # shard their seq dim over "model".
                                     # Off for SSM/hybrid (the SSD chunk
                                     # scan would serialize across shards).

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers {self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}")

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def has_attention(self) -> bool:
        return any(s.mixer.startswith("attn") for s in self.pattern)

    @property
    def has_ssm(self) -> bool:
        return any(s.mixer == "mamba" for s in self.pattern)

    @property
    def is_decoder(self) -> bool:
        return self.causal

    def param_count(self) -> int:
        """Total parameters (exact, by construction rules below)."""
        from .lm import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of E experts)."""
        from .lm import count_params
        return count_params(self, active_only=True)

    def smoke(self) -> "ModelConfig":
        """A reduced config of the same family for CPU smoke tests."""
        pat = self.pattern
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * len(pat) if len(pat) <= 4 else len(pat),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            moe_experts=min(self.moe_experts, 4) if self.moe_experts else 0,
            moe_topk=min(self.moe_topk, 2) if self.moe_topk else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            remat=False,
        )
