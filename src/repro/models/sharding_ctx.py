"""Activation-sharding context — constraints the model applies when lowered
under a production mesh.

XLA's SPMD propagation through ``while`` loops (our group scan) can drop
the batch sharding of the loop carry and silently replicate activations
across the data axis (observed: 16× logits/activation blowup on the
single-pod mesh).  The fix is standard (MaxText does the same): re-assert
activation shardings *inside* the loop body with
``with_sharding_constraint``.

The model code stays mesh-agnostic: constraints are expressed as logical
axes ("batch" / "model" / None) and resolve against whatever mesh the
launcher installed via ``activation_sharding``; with no context installed
(unit tests, CPU smoke runs) ``constrain`` is the identity.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, batch_axes: Sequence[str],
                        model_axis: str = "model",
                        replicate_batch: bool = False):
    """``replicate_batch=True`` (decode_tp mode): "batch" constraints
    resolve to replicated — decode activations are KB-scale and weights are
    stationary 2-D sharded, so moving activations beats gathering weights.
    In this mode the logical axes "tp" (full data×model tensor axis) and
    "tpd" (the data part only) become active: the model pins its decode
    activations to the weight layout so XLA contracts with activation-sized
    psums instead of weight gathers; outside decode_tp both resolve to
    unconstrained."""
    token = _CTX.set((mesh, tuple(batch_axes), model_axis, replicate_batch))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint ("batch" | "model" | None per
    dim).  Indivisible dims degrade to unconstrained."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, batch_axes, model_axis, replicate_batch = ctx
    assert len(logical) == x.ndim, (logical, x.shape)
    if not replicate_batch and any(n in ("tp", "tpd") for n in logical):
        # "tp"/"tpd" call sites exist purely for decode_tp mode; outside it
        # they must not constrain AT ALL (a partial constraint here would
        # fight the train-mode propagation — observed ~2× compute blowup).
        return x
    spec = []
    for name, dim in zip(logical, x.shape):
        if name == "batch":
            if replicate_batch:
                spec.append(None)
                continue
            size = math.prod(mesh.shape[a] for a in batch_axes)
            if dim % size == 0:
                spec.append(batch_axes if len(batch_axes) > 1
                            else batch_axes[0])
            elif len(batch_axes) > 1 and dim % mesh.shape[batch_axes[-1]] == 0:
                spec.append(batch_axes[-1])
            else:
                spec.append(None)
        elif name == "model":
            spec.append(model_axis if dim % mesh.shape[model_axis] == 0
                        else None)
        elif name == "tp":          # active only in decode_tp mode
            if not replicate_batch:
                spec.append(None)
                continue
            axes = tuple(batch_axes) + (model_axis,)
            size = math.prod(mesh.shape[a] for a in axes)
            spec.append(axes if dim % size == 0 else None)
        elif name == "tpd":         # the data part of the tensor axis
            if not replicate_batch:
                spec.append(None)
                continue
            size = math.prod(mesh.shape[a] for a in batch_axes)
            if dim % size == 0:
                spec.append(batch_axes if len(batch_axes) > 1
                            else batch_axes[0])
            else:
                spec.append(None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
