"""Mixture-of-experts FFN: top-k routing with capacity-bounded dispatch.

GShard/Mesh-TF-style dense formulation — token→expert assignment becomes
one-hot dispatch/combine tensors contracted with einsums, which is fully
static and SPMD-shardable: the expert dim of every large intermediate
([G,S,E,C], [E,G,C,d]) shards over the "model" mesh axis (expert
parallelism) when the expert count divides it (jamba 16e, qwen3-moe 128e);
otherwise experts stay replicated and each expert's d_ff shards over
"model" (grok-1 8e).

The dispatch tensor is built *per top-k slot* (the Mesh-TF formulation):
slot k's positions continue slot k-1's per-expert occupancy, so the peak
intermediate is one [G,S,E,C] tensor — never [G,S,K,E,C].

Aux losses: load-balancing (Switch Transformer) + router z-loss (ST-MoE).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    act: str = "swiglu"
    aux_loss_weight: float = 0.01
    z_loss_weight: float = 1e-3


def init_moe_params(rng, d_model: int, spec: MoESpec, dtype) -> Dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    E, F = spec.n_experts, spec.d_ff
    s_in = d_model ** -0.5
    s_out = F ** -0.5
    return {
        "router": (jax.random.normal(k1, (d_model, E)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (E, d_model, F)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(k3, (E, d_model, F)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k4, (E, F, d_model)) * s_out).astype(dtype),
    }


def capacity(tokens_per_group: int, spec: MoESpec) -> int:
    cap = int(tokens_per_group * spec.top_k * spec.capacity_factor
              / spec.n_experts)
    # hardware-aligned and never zero
    return max(8, -(-cap // 8) * 8)


def moe_ffn(params: Dict, x: jnp.ndarray, spec: MoESpec
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: [B, S, d] — groups are batch rows (G=B, group size S).

    Returns (output [B,S,d], aux metrics {aux_loss, z_loss, fraction_dropped}).
    """
    from .layers import ACTIVATIONS

    G, S, d = x.shape
    E, K = spec.n_experts, spec.top_k
    C = capacity(S, spec)

    logits = jnp.einsum(
        "gsd,de->gse", x.astype(jnp.float32), params["router"])  # [G,S,E]
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, expert_idx = jax.lax.top_k(probs, K)           # [G,S,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)     # renormalize

    # -- per-slot capacity assignment (Mesh-TF): slot k continues the
    #    per-expert occupancy left by slots < k -------------------------------
    dispatch = jnp.zeros((G, S, E, C), x.dtype)
    combine = jnp.zeros((G, S, E, C), x.dtype)
    base = jnp.zeros((G, E), jnp.int32)
    kept = jnp.zeros((), jnp.float32)
    for k in range(K):
        sel_k = jax.nn.one_hot(expert_idx[..., k], E, dtype=jnp.int32)  # [G,S,E]
        pos_k = jnp.cumsum(sel_k, axis=1) * sel_k - 1 + base[:, None, :] * sel_k
        within = (sel_k > 0) & (pos_k < C)                     # [G,S,E]
        oh = jax.nn.one_hot(jnp.clip(pos_k, 0, C - 1), C, dtype=x.dtype)
        disp_k = oh * within[..., None].astype(x.dtype)        # [G,S,E,C]
        dispatch = dispatch + disp_k
        combine = combine + gate_vals[..., k, None, None].astype(x.dtype) * disp_k
        base = base + jnp.sum(sel_k, axis=1)
        kept = kept + jnp.sum(within.astype(jnp.float32))

    # -- expert computation ----------------------------------------------------
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, x)      # [E,G,C,d]
    act = ACTIVATIONS[spec.act]
    h = act(
        jnp.einsum("egcd,edf->egcf", expert_in, params["w_gate"].astype(x.dtype)),
        jnp.einsum("egcd,edf->egcf", expert_in, params["w_up"].astype(x.dtype)))
    expert_out = jnp.einsum("egcf,efd->egcd", h,
                            params["w_down"].astype(x.dtype))  # [E,G,C,d]
    out = jnp.einsum("gsec,egcd->gsd", combine, expert_out)    # [G,S,d]

    # -- aux losses -----------------------------------------------------------
    # load balance: E * sum_e (fraction_tokens_e * mean_prob_e)
    top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
    frac_tokens = jnp.mean(top1, axis=(0, 1))                  # [E]
    mean_prob = jnp.mean(probs, axis=(0, 1))                   # [E]
    aux_loss = E * jnp.sum(frac_tokens * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - kept / (G * S * K)
    metrics = {
        "aux_loss": aux_loss * spec.aux_loss_weight,
        "z_loss": z_loss * spec.z_loss_weight,
        "fraction_dropped": dropped,
    }
    return out, metrics
