"""Model zoo: composable pure-JAX transformer / SSM / MoE stack."""
from .attention import AttnSpec, attention, decode_attention, init_kv_cache
from .config import LayerSpec, ModelConfig
from .layers import cross_entropy, rms_norm, softcap
from .lm import (
    count_params, decode_step, forward, init_cache, init_params, loss_fn,
    param_specs,
)
from .moe import MoESpec, moe_ffn
from .ssm import SSMSpec, ssd_chunked, ssm_forward

__all__ = [
    "ModelConfig", "LayerSpec", "AttnSpec", "MoESpec", "SSMSpec",
    "forward", "loss_fn", "decode_step", "init_params", "init_cache",
    "param_specs", "count_params",
    "attention", "decode_attention", "init_kv_cache",
    "moe_ffn", "ssm_forward", "ssd_chunked",
    "rms_norm", "softcap", "cross_entropy",
]
