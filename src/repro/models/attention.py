"""Attention: GQA/MQA, sliding windows, logit softcap, qk-norm, RoPE/M-RoPE.

Pure-jnp reference path (always available, used on CPU and by the dry-run);
the Pallas flash kernel (``repro.kernels.flash_attention``) is swapped in
via ``use_pallas`` on real TPU hardware.

Shapes: x [B, S, d]; weights wq [d, H, Dh], wk/wv [d, KVH, Dh],
wo [H, Dh, d].  Heads (or head_dim, for 16-indivisible head counts) are
sharded over the "model" mesh axis by the partition rules in
``repro.launch.sharding``.

GQA is computed with *grouped einsums* — query heads are reshaped to
[KV, G] groups and contracted directly against the un-expanded KV tensors.
Materializing repeated KV would multiply decode-cache reads by H/KV (8× for
most assigned archs), which is exactly the memory-roofline term decode is
bound by.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_mrope, apply_rope, rms_norm, softcap

NEG_INF = -2.0e38


@dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_softcap: float = 0.0
    sliding_window: int = 0       # 0 = full attention
    causal: bool = True
    mrope: bool = False
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)


def init_attn_params(rng, d_model: int, spec: AttnSpec, dtype) -> Dict:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    H, KV, Dh = spec.n_heads, spec.n_kv_heads, spec.head_dim
    s = d_model ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d_model, H, Dh)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, KV, Dh)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, KV, Dh)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H, Dh, d_model)) * s).astype(dtype),
    }
    if spec.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def _project_qkv(params, x, spec: AttnSpec, positions):
    """Returns q [B,S,H,Dh], k/v [B,S,KV,Dh] with rope + qk-norm applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if spec.mrope:
        q = apply_mrope(q, positions, theta=spec.rope_theta)
        k = apply_mrope(k, positions, theta=spec.rope_theta)
    else:
        q = apply_rope(q, positions, theta=spec.rope_theta)
        k = apply_rope(k, positions, theta=spec.rope_theta)
    return q, k, v


def _group_q(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """[B,S,H,Dh] -> [B,S,KV,G,Dh] with G = H // KV."""
    B, S, H, Dh = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, Dh)


def _mask_bias(q_pos, k_pos, spec: AttnSpec):
    """Additive bias [Sq, Sk] encoding causality + sliding window."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), dtype=bool)
    if spec.causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if spec.sliding_window:
        ok &= k_pos[None, :] > q_pos[:, None] - spec.sliding_window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_naive(qg, k, v, q_pos, k_pos, spec: AttnSpec) -> jnp.ndarray:
    """Reference S²-materializing attention. qg [B,Sq,KV,G,Dh]."""
    scale = spec.query_scale or spec.head_dim ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    if spec.attn_softcap:
        scores = softcap(scores, spec.attn_softcap)
    scores = scores + _mask_bias(q_pos, k_pos, spec)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(qg.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def _attend_chunked(qg, k, v, q_pos, k_pos, spec: AttnSpec,
                    chunk: int, unroll: bool = False) -> jnp.ndarray:
    """Online-softmax attention over KV chunks (the XLA-level flash
    formulation): peak intermediate is [B,KV,G,Sq,chunk] instead of
    [...,Sq,Sk].  Exact — same math as _attend_naive."""
    B, Sq, KV, G, Dh = qg.shape
    Sk = k.shape[1]
    nc = Sk // chunk
    assert nc * chunk == Sk, (Sk, chunk)
    scale = spec.query_scale or spec.head_dim ** -0.5
    kr = jnp.moveaxis(k.reshape(B, nc, chunk, KV, Dh), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nc, chunk, KV, Dh), 1, 0)
    kpr = jnp.moveaxis(k_pos.reshape(nc, chunk), 0, 0)

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, KV, G, Sq, Dh), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        k_c, v_c, kp_c = inp
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_c).astype(jnp.float32) \
            * scale
        if spec.attn_softcap:
            s = softcap(s, spec.attn_softcap)
        s = s + _mask_bias(q_pos, kp_c, spec)[None, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(qg.dtype), v_c)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kr, vr, kpr),
                                  unroll=nc if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]             # [B,KV,G,Sq,Dh]
    return jnp.moveaxis(out, 3, 1).astype(qg.dtype)          # [B,Sq,KV,G,Dh]


def attention(params: Dict, x: jnp.ndarray, spec: AttnSpec, *,
              positions: Optional[jnp.ndarray] = None,
              chunk: int = 0, unroll: bool = False,
              use_pallas: bool = False) -> jnp.ndarray:
    """Full-sequence attention (training / prefill). x: [B, S, d]."""
    B, S, _ = x.shape
    if positions is None:
        pos1d = jnp.arange(S, dtype=jnp.int32)
        positions = jnp.broadcast_to(pos1d, (3, B, S)) if spec.mrope \
            else jnp.broadcast_to(pos1d, (B, S))
    q, k, v = _project_qkv(params, x, spec, positions)
    pos1d = positions[0] if spec.mrope else positions
    q_pos = pos1d[0]
    H = spec.n_heads
    if use_pallas:
        from ..kernels.flash_attention import gqa_flash_attention
        bq = max(min(512, S), 16)
        ctx = gqa_flash_attention(
            q, k, v, causal=spec.causal, window=spec.sliding_window,
            softcap=spec.attn_softcap, scale=spec.query_scale,
            block_q=bq, block_k=bq)
        ctx = ctx.reshape(B, S, H, spec.head_dim)
        return jnp.einsum("bqhk,hkd->bqd", ctx,
                          params["wo"].astype(x.dtype))
    qg = _group_q(q, spec.n_kv_heads)                        # [B,S,KV,G,Dh]
    if chunk and S % min(chunk, S) == 0:
        ctx = _attend_chunked(qg, k, v, q_pos, q_pos, spec, min(chunk, S),
                              unroll=unroll)
    else:
        ctx = _attend_naive(qg, k, v, q_pos, q_pos, spec)
    ctx = ctx.reshape(B, S, H, spec.head_dim)
    return jnp.einsum("bqhk,hkd->bqd", ctx, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode path with KV cache.
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, spec: AttnSpec, dtype
                  ) -> Dict[str, jnp.ndarray]:
    KV, Dh = spec.n_kv_heads, spec.head_dim
    return {
        "k": jnp.zeros((batch, max_len, KV, Dh), dtype),
        "v": jnp.zeros((batch, max_len, KV, Dh), dtype),
    }


def decode_attention(params: Dict, x: jnp.ndarray, cache: Dict,
                     pos: jnp.ndarray, spec: AttnSpec
                     ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step. x: [B, 1, d]; cache k/v [B, Smax, KV, Dh];
    pos: scalar int32 — the index being written."""
    B = x.shape[0]
    Smax = cache["k"].shape[1]
    if spec.mrope:
        positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, spec, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    qg = _group_q(q, spec.n_kv_heads)                        # [B,1,KV,G,Dh]
    scale = spec.query_scale or spec.head_dim ** -0.5
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", qg, k_cache.astype(x.dtype)
    ).astype(jnp.float32) * scale
    if spec.attn_softcap:
        scores = softcap(scores, spec.attn_softcap)
    kpos = jnp.arange(Smax, dtype=jnp.int32)
    ok = kpos <= pos
    if spec.sliding_window:
        ok &= kpos > pos - spec.sliding_window
    scores = jnp.where(ok[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_cache.astype(x.dtype))
    ctx = ctx.reshape(B, 1, spec.n_heads, spec.head_dim)
    # decode_tp: heads over "model", head_dim over the data axes — matches
    # wo's stationary layout so the output contraction psums activations
    from .sharding_ctx import constrain
    ctx = constrain(ctx, "batch", None, "model", "tpd")
    out = jnp.einsum("bqhk,hkd->bqd", ctx, params["wo"].astype(x.dtype))
    return out, {"k": k_cache, "v": v_cache}
