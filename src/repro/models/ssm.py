"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060), pure JAX.

The selective state-space layer with scalar-identity A per head:

    h_t = exp(dt_t·A) * h_{t-1} + dt_t * B_t ⊗ x_t          (per head)
    y_t = C_t · h_t + D * x_t

Training uses the *chunked* SSD algorithm: the sequence is split into
chunks; within a chunk the quadratic (attention-like) form computes
token-token interactions, and a lightweight scan over chunk boundaries
carries the state — matmul-dominant work, matching the paper's formulation
(this is also what the Pallas kernel tiles; see ``repro.kernels.ssd_scan``).
Decode is the O(1) recurrence.

TP note (DESIGN.md §6): projections are kept *separate* (z, x, B, C, dt)
rather than fused as in the reference CUDA implementation.  A fused
in_proj puts the z|x|B|C|dt boundaries inside one output axis, which never
aligns with a 16-way model shard; separate matrices let x/z shard by whole
SSD heads (d_inner = H·P with H % 16 == 0 for both assigned SSM archs)
while the small B/C/dt streams replicate or shard freely.  The math is
identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import rms_norm


@dataclass(frozen=True)
class SSMSpec:
    d_inner: int                  # expand * d_model
    n_heads: int                  # d_inner // headdim
    headdim: int
    d_state: int                  # N
    conv_width: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1


def init_ssm_params(rng, d_model: int, spec: SSMSpec, dtype) -> Dict:
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(rng, 7)
    Din, H, N, W = spec.d_inner, spec.n_heads, spec.d_state, spec.conv_width
    s = d_model ** -0.5
    dt = jnp.exp(jax.random.uniform(k6, (H,)) *
                 (jnp.log(spec.dt_max) - jnp.log(spec.dt_min)) +
                 jnp.log(spec.dt_min))
    return {
        "in_z": (jax.random.normal(k1, (d_model, Din)) * s).astype(dtype),
        "in_x": (jax.random.normal(k2, (d_model, Din)) * s).astype(dtype),
        "in_B": (jax.random.normal(k3, (d_model, N)) * s).astype(dtype),
        "in_C": (jax.random.normal(k4, (d_model, N)) * s).astype(dtype),
        "in_dt": (jax.random.normal(k5, (d_model, H)) * s).astype(dtype),
        "conv_x": (jax.random.normal(k7, (W, Din))
                   * (W ** -0.5)).astype(dtype),
        "conv_B": (jax.random.normal(jax.random.fold_in(k7, 1), (W, N))
                   * (W ** -0.5)).astype(dtype),
        "conv_C": (jax.random.normal(jax.random.fold_in(k7, 2), (W, N))
                   * (W ** -0.5)).astype(dtype),
        "conv_bias_x": jnp.zeros((Din,), dtype),
        "conv_bias_B": jnp.zeros((N,), dtype),
        "conv_bias_C": jnp.zeros((N,), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((Din,), dtype),
        "out_proj": (jax.random.normal(jax.random.fold_in(k7, 3),
                                       (Din, d_model))
                     * (Din ** -0.5)).astype(dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over [B,S,Ch] with width-W filter [W,Ch].
    If ``state`` [B, W-1, Ch] is given (decode), uses it as left context and
    returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (W - 1,) + x.shape[2:], x.dtype)
        ctx = jnp.concatenate([pad, x], axis=1)
    else:
        ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(ctx[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(W))
    out = jax.nn.silu(out + b.astype(x.dtype))
    new_state = ctx[:, -(W - 1):]
    return out, new_state


def _project(params, x):
    """x [B,S,d] -> z, xs [B,S,Din], Bc, Cc [B,S,N], dt [B,S,H]."""
    z = jnp.einsum("bsd,de->bse", x, params["in_z"].astype(x.dtype))
    xs = jnp.einsum("bsd,de->bse", x, params["in_x"].astype(x.dtype))
    Bc = jnp.einsum("bsd,dn->bsn", x, params["in_B"].astype(x.dtype))
    Cc = jnp.einsum("bsd,dn->bsn", x, params["in_C"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, params["in_dt"].astype(x.dtype))
    return z, xs, Bc, Cc, dt


def ssd_chunked(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bc: jnp.ndarray, Cc: jnp.ndarray, D: jnp.ndarray,
                chunk: int,
                h0: Optional[jnp.ndarray] = None,
                unroll: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    xh [B,S,H,P] (P=headdim), dt [B,S,H] (softplus-ed), A [H] (negative),
    Bc/Cc [B,S,N], D [H].  Returns (y [B,S,H,P], final state [B,H,P,N]).
    """
    B_, S, H, P = xh.shape
    N = Bc.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    # per-step log decay: a_t = dt_t * A  (negative)
    a = dt * A[None, None, :]                                  # [B,S,H]
    xr = xh.reshape(B_, nc, chunk, H, P)
    ar = a.reshape(B_, nc, chunk, H)
    dtr = dt.reshape(B_, nc, chunk, H)
    Br = Bc.reshape(B_, nc, chunk, N)
    Cr = Cc.reshape(B_, nc, chunk, N)

    # cumulative decay within chunk: L[t] = sum_{i<=t} a_i
    acs = jnp.cumsum(ar, axis=2)                               # [B,nc,c,H]

    # ---- intra-chunk (quadratic, attention-like) ----
    # scores[t,s] = (C_t · B_s) * exp(acs_t - acs_s) * dt_s  for s <= t
    diff = acs[:, :, :, None, :] - acs[:, :, None, :, :]       # [B,nc,c,c,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bnck,bnmk->bncm", Cr, Br)                 # C_t · B_s
    scores = cb[..., None] * decay * dtr[:, :, None, :, :]     # [B,nc,c,c,H]
    y_intra = jnp.einsum("bncsh,bnshp->bnchp", scores, xr)

    # ---- chunk-boundary states ----
    # state contribution of chunk j: sum_s exp(acs_end - acs_s) dt_s B_s x_s
    tail_decay = jnp.exp(acs[:, :, -1:, :] - acs)              # [B,nc,c,H]
    chunk_state = jnp.einsum("bnsh,bnsk,bnshp->bnhpk",
                             tail_decay * dtr, Br, xr)         # [B,nc,H,P,N]

    # scan over chunks: h_{j+1} = exp(sum a in chunk j) h_j + chunk_state_j
    chunk_decay = jnp.exp(acs[:, :, -1, :])                    # [B,nc,H]
    if h0 is None:
        h0 = jnp.zeros((B_, H, P, N), xh.dtype)

    def scan_fn(h, inp):
        cd, cs = inp                                           # [B,H], [B,H,P,N]
        h_out = h                                              # state BEFORE chunk
        h_new = cd[..., None, None] * h + cs
        return h_new, h_out

    cd_swapped = jnp.moveaxis(chunk_decay, 1, 0)               # [nc,B,H]
    cs_swapped = jnp.moveaxis(chunk_state, 1, 0)               # [nc,B,H,P,N]
    h_final, h_before = jax.lax.scan(scan_fn, h0, (cd_swapped, cs_swapped),
                                     unroll=nc if unroll else 1)
    h_before = jnp.moveaxis(h_before, 0, 1)                    # [B,nc,H,P,N]

    # ---- inter-chunk: y += C_t · (decay_to_t * h_before_chunk) ----
    head_decay = jnp.exp(acs)                                  # [B,nc,c,H]
    y_inter = jnp.einsum("bnck,bnch,bnhpk->bnchp",
                         Cr, head_decay, h_before)
    y = (y_intra + y_inter).reshape(B_, S, H, P)
    y = y + xh * D[None, None, :, None]
    return y, h_final


def ssm_forward(params: Dict, x: jnp.ndarray, spec: SSMSpec,
                unroll: bool = False) -> jnp.ndarray:
    """Training / prefill forward. x: [B,S,d] -> [B,S,d]."""
    H, P = spec.n_heads, spec.headdim
    z, xs, Bc, Cc, dt = _project(params, x)
    xs, _ = _causal_conv(xs, params["conv_x"], params["conv_bias_x"])
    Bc, _ = _causal_conv(Bc, params["conv_B"], params["conv_bias_B"])
    Cc, _ = _causal_conv(Cc, params["conv_C"], params["conv_bias_C"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                              # [H] negative
    xh = xs.reshape(*xs.shape[:2], H, P)
    y, _ = ssd_chunked(xh, dt.astype(x.dtype), A.astype(x.dtype),
                       Bc, Cc, params["D"].astype(x.dtype), spec.chunk,
                       unroll=unroll)
    y = y.reshape(*x.shape[:2], spec.d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode (O(1) recurrent step)
# ---------------------------------------------------------------------------

def init_ssm_cache(batch: int, spec: SSMSpec, dtype) -> Dict[str, jnp.ndarray]:
    H, P, N, W = spec.n_heads, spec.headdim, spec.d_state, spec.conv_width
    return {
        "conv_x": jnp.zeros((batch, W - 1, spec.d_inner), dtype),
        "conv_B": jnp.zeros((batch, W - 1, N), dtype),
        "conv_C": jnp.zeros((batch, W - 1, N), dtype),
        "ssd": jnp.zeros((batch, H, P, N), dtype),
    }


def decode_ssm(params: Dict, x: jnp.ndarray, cache: Dict, spec: SSMSpec
               ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: x [B,1,d] -> (y [B,1,d], new cache)."""
    from .sharding_ctx import constrain

    H, P = spec.n_heads, spec.headdim
    z, xs, Bc, Cc, dt = _project(params, x)
    # decode_tp: pin the inner-dim activations to the stationary weight
    # layout so the out_proj contraction psums 2 MB activations instead of
    # gathering 0.25 GB weights (no-op outside decode_tp mode)
    z = constrain(z, "batch", None, "tp")
    xs = constrain(xs, "batch", None, "tp")
    xs, conv_x = _causal_conv(xs, params["conv_x"], params["conv_bias_x"],
                              state=cache["conv_x"])
    Bc, conv_B = _causal_conv(Bc, params["conv_B"], params["conv_bias_B"],
                              state=cache["conv_B"])
    Cc, conv_C = _causal_conv(Cc, params["conv_C"], params["conv_bias_C"],
                              state=cache["conv_C"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,1,H]
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(-1, H, P)                                  # [B,H,P]
    decay = jnp.exp(dt[:, 0, :] * A[None, :])                  # [B,H]
    h = cache["ssd"].astype(jnp.float32)
    h = decay[..., None, None] * h + jnp.einsum(
        "bh,bk,bhp->bhpk", dt[:, 0, :], Bc[:, 0].astype(jnp.float32),
        xh.astype(jnp.float32))
    y = jnp.einsum("bk,bhpk->bhp", Cc[:, 0].astype(jnp.float32), h)
    y = y + xh.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(-1, 1, spec.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    y = constrain(y, "batch", None, "tp")
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    return out, {"conv_x": conv_x.astype(cache["conv_x"].dtype),
                 "conv_B": conv_B.astype(cache["conv_B"].dtype),
                 "conv_C": conv_C.astype(cache["conv_C"].dtype),
                 "ssd": h.astype(cache["ssd"].dtype)}
