"""Language-model assembly: embedding → scanned block groups → head.

Parameters are nested dicts; per-group block parameters are *stacked* along
a leading ``n_groups`` axis and consumed by ``lax.scan`` (HLO size stays
O(pattern length), independent of depth).  Heterogeneous patterns (jamba's
7:1 mamba:attn interleave, gemma-2's local/global alternation, MoE
periods) are unrolled *inside* the scan body; each layer kind keeps its own
stacked sub-tree indexed statically within the group.

Three entry points per config:
  * ``forward(params, batch)``          — logits for training/prefill
  * ``loss_fn(params, batch)``          — mean CE + MoE aux losses
  * ``decode_step(params, cache, tok)`` — one-token serve step with cache
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    AttnSpec, attention, decode_attention, init_attn_params, init_kv_cache,
)
from .config import LayerSpec, ModelConfig
from .layers import ACTIVATIONS, cross_entropy, rms_norm, softcap
from .moe import MoESpec, init_moe_params, moe_ffn
from .sharding_ctx import constrain
from .ssm import (
    SSMSpec, decode_ssm, init_ssm_cache, init_ssm_params, ssm_forward,
)


# ---------------------------------------------------------------------------
# Spec builders
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig, spec: LayerSpec) -> AttnSpec:
    sliding = cfg.sliding_window if spec.mixer in ("attn_local",) else 0
    if spec.mixer == "attn" and cfg.sliding_window and not cfg.has_ssm:
        # archs whose only attention is sliding (none assigned currently)
        sliding = cfg.sliding_window
    return AttnSpec(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm, attn_softcap=cfg.attn_softcap,
        sliding_window=sliding, causal=cfg.causal, mrope=cfg.mrope)


def moe_spec(cfg: ModelConfig) -> MoESpec:
    return MoESpec(n_experts=cfg.moe_experts, top_k=cfg.moe_topk,
                   d_ff=cfg.moe_d_ff or cfg.d_ff,
                   capacity_factor=cfg.capacity_factor, act=cfg.act)


def ssm_spec(cfg: ModelConfig) -> SSMSpec:
    return SSMSpec(d_inner=cfg.d_inner, n_heads=cfg.ssm_heads,
                   headdim=cfg.ssm_headdim, d_state=cfg.ssm_state,
                   conv_width=cfg.ssm_conv_width, chunk=cfg.ssm_chunk)


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _init_mlp(rng, cfg: ModelConfig, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * f ** -0.5).astype(dtype),
    }


def _init_group(rng, cfg: ModelConfig) -> Dict:
    """Parameters for ONE group (the pattern applied once)."""
    dtype = jnp.dtype(cfg.param_dtype)
    out: Dict[str, Any] = {}
    for i, spec in enumerate(cfg.pattern):
        rng, k_mix, k_ffn = jax.random.split(rng, 3)
        layer: Dict[str, Any] = {
            "pre_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if spec.mixer.startswith("attn"):
            layer["attn"] = init_attn_params(
                k_mix, cfg.d_model, attn_spec(cfg, spec), dtype)
        elif spec.mixer == "mamba":
            layer["mamba"] = init_ssm_params(
                k_mix, cfg.d_model, ssm_spec(cfg), dtype)
        if spec.ffn == "mlp":
            layer["ffn_norm"] = jnp.ones((cfg.d_model,), dtype)
            layer["mlp"] = _init_mlp(k_ffn, cfg, dtype)
        elif spec.ffn == "moe":
            layer["ffn_norm"] = jnp.ones((cfg.d_model,), dtype)
            layer["moe"] = init_moe_params(k_ffn, cfg.d_model, moe_spec(cfg),
                                           dtype)
        out[f"layer{i}"] = layer
    return out


def init_params(rng, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    rng_embed, rng_blocks, rng_head = jax.random.split(rng, 3)
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = (jax.random.normal(
            rng_embed, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype)
    # stacked group params via vmap over per-group init
    group_rngs = jax.random.split(rng_blocks, cfg.n_groups)
    params["blocks"] = jax.vmap(lambda r: _init_group(r, cfg))(group_rngs)
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not (cfg.tie_embeddings and cfg.input_mode == "tokens"):
        params["unembed"] = (jax.random.normal(
            rng_head, (cfg.d_model, cfg.vocab_size))
            * cfg.d_model ** -0.5).astype(dtype)
    return params


def param_specs(cfg: ModelConfig) -> Dict:
    """ShapeDtypeStructs for the parameter tree — no allocation."""
    return jax.eval_shape(
        lambda: init_params(jax.random.key(0), cfg))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    specs = param_specs(cfg)

    def moe_active_fraction(path_leaf_shape) -> float:
        return cfg.moe_topk / cfg.moe_experts

    total = 0
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= s
        keys = jax.tree_util.keystr(path)
        if active_only and ("'moe'" in keys) and ("router" not in keys):
            n = int(n * cfg.moe_topk / cfg.moe_experts)
        total += n
    return total


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _mlp(layer: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    act = ACTIVATIONS[cfg.act]
    h = act(jnp.einsum("bsd,df->bsf", x, layer["w_gate"].astype(x.dtype)),
            jnp.einsum("bsd,df->bsf", x, layer["w_up"].astype(x.dtype)))
    # "tp" pins h to the stationary weight layout in decode_tp mode (no-op
    # during training — resolves to unconstrained)
    h = constrain(h, "batch", None, "tp")
    return jnp.einsum("bsf,fd->bsd", h, layer["w_down"].astype(x.dtype))


def _apply_group(cfg: ModelConfig, group_params: Dict, x: jnp.ndarray,
                 positions: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the pattern once. Returns (x, aux_loss_sum)."""
    # Re-assert the activation sharding inside the scan body: SPMD
    # propagation through while loops can otherwise replicate the carry.
    # With seq_shard the remat stash (the dominant training buffer) also
    # shards its sequence dim over "model".
    x = constrain(x, "batch", "model" if cfg.seq_shard else None, None)
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(cfg.pattern):
        layer = group_params[f"layer{i}"]
        h = rms_norm(x, layer["pre_norm"], zero_centered=cfg.zero_centered_norm)
        if spec.mixer.startswith("attn"):
            mix = attention(layer["attn"], h, attn_spec(cfg, spec),
                            positions=positions, chunk=cfg.attn_chunk,
                            unroll=cfg.scan_unroll,
                            use_pallas=cfg.use_pallas)
        elif spec.mixer == "mamba":
            mix = ssm_forward(layer["mamba"], h, ssm_spec(cfg),
                              unroll=cfg.scan_unroll)
        else:
            raise ValueError(spec.mixer)
        x = x + mix
        if spec.ffn == "mlp":
            h = rms_norm(x, layer["ffn_norm"],
                         zero_centered=cfg.zero_centered_norm)
            x = x + _mlp(layer["mlp"], h, cfg)
        elif spec.ffn == "moe":
            h = rms_norm(x, layer["ffn_norm"],
                         zero_centered=cfg.zero_centered_norm)
            out, metrics = moe_ffn(layer["moe"], h, moe_spec(cfg))
            x = x + out
            aux = aux + metrics["aux_loss"] + metrics["z_loss"]
    return x, aux


def forward(params: Dict, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B,S,V], aux_loss scalar).

    batch: {"tokens": [B,S] int32} or {"embeddings": [B,S,d]};
    optional {"positions": [B,S] or [3,B,S] for mrope}.
    """
    compute = jnp.dtype(cfg.compute_dtype)
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(compute)
    else:
        x = batch["embeddings"].astype(compute)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute)
    x = constrain(x, "batch", "model" if cfg.seq_shard else None, None)
    positions = batch.get("positions")

    body = functools.partial(_apply_group, cfg)
    if cfg.remat:
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat_policy == "nothing" else
                  jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(body, policy=policy)

    def scan_fn(carry, group_params):
        x, aux = carry
        x, aux_g = body(group_params, x, positions)
        return (x, aux + aux_g), None

    (x, aux), _ = jax.lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)), params["blocks"],
        unroll=cfg.n_groups if cfg.scan_unroll else 1)
    x = rms_norm(x, params["final_norm"], zero_centered=cfg.zero_centered_norm)
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    if cfg.seq_shard:
        logits = constrain(logits, "batch", "model", None)
    else:
        logits = constrain(logits, "batch", None, "model")
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


def loss_fn(params: Dict, batch: Dict, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(params, batch, cfg)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode with caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Nested cache: one stacked entry per layer kind per group."""
    dtype = jnp.dtype(cfg.compute_dtype)
    one_group: Dict[str, Any] = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.mixer.startswith("attn"):
            one_group[f"layer{i}"] = init_kv_cache(
                batch, max_len, attn_spec(cfg, spec), dtype)
        elif spec.mixer == "mamba":
            one_group[f"layer{i}"] = init_ssm_cache(batch, ssm_spec(cfg), dtype)
    # stack over groups
    return jax.tree.map(
        lambda l: jnp.zeros((cfg.n_groups,) + l.shape, l.dtype), one_group)


def _decode_group(cfg: ModelConfig, group_params: Dict, group_cache: Dict,
                  x: jnp.ndarray, pos: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    new_cache: Dict[str, Any] = {}
    for i, spec in enumerate(cfg.pattern):
        layer = group_params[f"layer{i}"]
        h = rms_norm(x, layer["pre_norm"], zero_centered=cfg.zero_centered_norm)
        if spec.mixer.startswith("attn"):
            mix, new_cache[f"layer{i}"] = decode_attention(
                layer["attn"], h, group_cache[f"layer{i}"], pos,
                attn_spec(cfg, spec))
        elif spec.mixer == "mamba":
            mix, new_cache[f"layer{i}"] = decode_ssm(
                layer["mamba"], h, group_cache[f"layer{i}"], ssm_spec(cfg))
        x = x + mix
        if spec.ffn == "mlp":
            h = rms_norm(x, layer["ffn_norm"],
                         zero_centered=cfg.zero_centered_norm)
            x = x + _mlp(layer["mlp"], h, cfg)
        elif spec.ffn == "moe":
            h = rms_norm(x, layer["ffn_norm"],
                         zero_centered=cfg.zero_centered_norm)
            out, _ = moe_ffn(layer["moe"], h, moe_spec(cfg))
            x = x + out
    return x, new_cache


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, Dict]:
    """One serve step. tokens [B] int32 (or embeddings [B,d]); pos scalar.
    Returns (logits [B,V], new cache)."""
    compute = jnp.dtype(cfg.compute_dtype)
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(compute)
    else:
        x = tokens[:, None, :].astype(compute)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute)
    x = constrain(x, "batch", None, None)

    def scan_fn(carry, xs):
        x = carry
        group_params, group_cache = xs
        x = constrain(x, "batch", None, None)
        x, new_group_cache = _decode_group(cfg, group_params, group_cache,
                                           x, pos)
        return x, new_group_cache

    x, new_cache = jax.lax.scan(scan_fn, x, (params["blocks"], cache),
                                unroll=cfg.n_groups if cfg.scan_unroll else 1)
    x = rms_norm(x, params["final_norm"], zero_centered=cfg.zero_centered_norm)
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits[:, 0], new_cache
