"""Shared neural layers (pure JAX, no flax): norms, activations, RoPE.

Conventions:
  * params are plain nested dicts of jnp arrays, stored in ``param_dtype``
    (fp32 by default) and cast to ``compute_dtype`` (bf16) inside ops;
  * RoPE uses the *interleaved-pairs* formulation (GPT-NeoX style): pairs
    ``(2i, 2i+1)`` rotate together.  Pairs stay device-local when head_dim
    is sharded across the model axis — which is how archs with
    16-indivisible head counts (qwen3-14b: 40H, gemma-2b: 8H, qwen2-vl:
    28H) are tensor-parallelized (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
             zero_centered: bool = False) -> jnp.ndarray:
    """RMSNorm; ``zero_centered`` uses the Gemma (1+scale) convention."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    if zero_centered:
        w = 1.0 + w
    return (y * w).astype(dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate) * up


def geglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(gate, approximate=True) * up


ACTIVATIONS = {"swiglu": swiglu, "geglu": geglu}


# ---------------------------------------------------------------------------
# Rotary position embeddings (interleaved-pairs formulation).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Per-pair inverse frequencies, shape [head_dim // 2]."""
    k = jnp.arange(head_dim // 2, dtype=jnp.float32)
    return 1.0 / (theta ** (2.0 * k / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                                # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv      # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x_even = x32[..., 0::2]
    x_odd = x32[..., 1::2]
    out_even = x_even * cos - x_odd * sin
    out_odd = x_odd * cos + x_even * sin
    out = jnp.stack([out_even, out_odd], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Qwen2-VL proportions (16, 24, 24)/64 of the pair dim, any head_dim."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray,
                sections: Optional[Tuple[int, int, int]] = None,
                theta: float = 10000.0) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the pair dimension is split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  ``positions``: [3, ..., S] (t/h/w ids; equal for pure text).

    x: [..., S, H, D] with sum(sections) == D // 2.
    """
    D = x.shape[-1]
    if sections is None:
        sections = mrope_sections(D)
    assert sum(sections) == D // 2, (sections, D)
    inv = rope_freqs(D, theta)                                # [D/2]
    # build a per-pair position by selecting the section's position stream
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=D // 2)
    # positions: [3, ..., S] -> [..., S, D/2] by gathering along axis 0
    pos = jnp.take(positions, sec_id, axis=0)                 # [D/2, ..., S]? no:
    # jnp.take with axis=0 gives [D/2, ..., S]; move pair axis last
    pos = jnp.moveaxis(pos, 0, -1)                            # [..., S, D/2]
    ang = pos.astype(jnp.float32) * inv
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x_even = x32[..., 0::2]
    x_odd = x32[..., 1::2]
    out_even = x_even * cos - x_odd * sin
    out_odd = x_odd * cos + x_even * sin
    out = jnp.stack([out_even, out_odd], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token CE in fp32. logits [..., V], labels [...] int.

    The gold logit is extracted with a one-hot contraction, NOT
    take_along_axis: a positional gather over a vocab-sharded logits tensor
    forces SPMD to all-gather the full [B,S,V] fp32 logits (12+ GiB/device
    at 256k vocab); the one-hot product stays sharded and reduces with one
    tiny psum.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
